"""Workload trace generators: the contract, the hash, and STREAM.

The paper's pitch is exploring CXL expanders under *realistic* software —
LLM inference traffic, latency-bound pointer chasing, random updates — not
just bandwidth kernels.  Every workload in this package implements one
contract (:class:`Workload`) with two mirrored generators:

``device_trace``
    Pure ``jax``/``lax`` ops producing the ``(addr, is_write[, tier])``
    arrays directly on device — the batched engine
    (:mod:`repro.core.engine`) stacks them without ever materializing the
    trace on the host.
``host_trace``
    The NumPy twin of the same sequence, and the parity oracle: the device
    and host traces must be **element-for-element equal**, so stats
    computed from either are bitwise identical (test-enforced in
    ``tests/test_workloads.py`` and asserted inside ``benchmarks/run.py
    --only workloads``).

Scope of the oracle: most generators execute one shared integer recurrence
(a SplitMix-style 32-bit avalanche hash, full-period affine rings) under
an ``xp`` array module, so the check pins jax/XLA uint32/int32 semantics
and the device-side expansion against NumPy's — it is a cross-*backend*
equivalence, not an independent reimplementation (the scenario logic
itself, e.g. ``kv_decode``'s recorded serving loop, is shared).  The
pointer chase is the exception: its device side is a ``lax.scan`` and its
host side a plain Python loop, genuinely independent derivations of the
same ring.

Seeding
-------
Every stochastic workload carries an explicit ``seed`` field (part of its
frozen dataclass identity).  Same seed => bitwise-identical traces on every
backend; different seeds => different address sequences.  There is no
hidden global RNG state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as stream_mod
from repro.core.machine import CPUModel
from repro.core.numa import LINES_PER_PAGE
from repro.core.spec import CACHELINE_BYTES

Array = jax.Array


# ---------------------------------------------------------------------------
# Shared integer recurrences (identical under numpy and jax.numpy)
# ---------------------------------------------------------------------------
def mix32(x, seed: int, xp):
    """SplitMix-style 32-bit avalanche hash, identical under ``np``/``jnp``.

    Parameters
    ----------
    x : array-like of uint-compatible ints
        Counter values to hash (arrays, not scalars — NumPy only wraps
        integer overflow silently for arrays).
    seed : int
        Stream selector, folded in before the first round.
    xp : module
        ``numpy`` or ``jax.numpy``; both wrap uint32 arithmetic mod 2**32.

    Returns
    -------
    array of uint32
        Hashed values, bitwise identical across the two array modules.
    """
    x = xp.asarray(x, xp.uint32)
    x = (x ^ xp.uint32(seed & 0xFFFFFFFF)) * xp.uint32(0x9E3779B1)
    x = (x ^ (x >> 16)) * xp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * xp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def mix32_int(x: int) -> int:
    """Scalar Python-int twin of :func:`mix32` (parameter derivation)."""
    x &= 0xFFFFFFFF
    x = (x * 0x9E3779B1) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    return x ^ (x >> 16)


def full_period_affine(n: int, seed: int) -> Tuple[int, int, int]:
    """Parameters of a full-period affine ring ``pos -> (a*pos + c) mod n``.

    Satisfies the Hull–Dobell theorem for *any* ``n >= 2``: ``a - 1`` is
    divisible by every prime factor of ``n`` (and by 4 when ``4 | n``) and
    ``gcd(c, n) == 1`` — so iterating the map from any start visits every
    residue exactly once per lap and returns to the start.  This is the
    "permuted ring" the pointer-chase workload walks.

    Parameters
    ----------
    n : int
        Ring size (number of cache lines).
    seed : int
        Selects ``c`` and the start position ``p0``.

    Returns
    -------
    (a, c, p0) : tuple of int
        Multiplier, increment and start position, all in ``[0, n)``.
    """
    if n < 2:
        raise ValueError(f"ring needs >= 2 lines, got {n}")
    x, d, m = n, 2, 1
    while d * d <= x:
        if x % d == 0:
            m *= d
            while x % d == 0:
                x //= d
        d += 1
    if x > 1:
        m *= x
    if n % 4 == 0 and m % 4 != 0:
        m *= 2
    a = (m + 1) % n
    c = mix32_int(seed) % n
    while math.gcd(c, n) != 1:
        c = (c + 1) % n
    p0 = mix32_int(seed ^ 0x5BF03635) % n
    if a * (n - 1) + c >= 2 ** 31:
        raise ValueError(f"ring of {n} lines overflows int32 chase "
                         f"arithmetic (a={a})")
    return a, c, p0


# ---------------------------------------------------------------------------
# The workload contract
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    """One generated trace: per-access address/write streams (+ tier).

    Attributes
    ----------
    addr : (N,) int32 array
        Window-relative cacheline indices, device (`jnp`) or host (`np`).
    is_write : (N,) int32/bool array
        1/True for stores.
    n_pages : int
        Pages spanned by the address space — the domain a page-placement
        policy (:mod:`repro.core.numa`) maps over.
    tier : (N,) int32 array, optional
        Per-access DRAM(0)/CXL(1) intent.  ``None`` means the placement
        policy decides (STREAM, GUPS, pointer-chase, MoE streaming);
        ``kv_decode`` supplies it from the paged KV cache's tier map, in
        which case the policy axis is ignored and CXL-destined lines still
        decode through the route's committed HDM programs.
    """
    addr: Array
    is_write: Array
    n_pages: int
    tier: Optional[Array] = None

    @property
    def n_accesses(self) -> int:
        return int(self.addr.shape[0])


class Workload:
    """Base class: a named, seedable, footprint-scalable trace generator.

    Subclasses are frozen dataclasses (hashable — they ride the
    :class:`repro.core.engine.SweepSpec` ``workloads`` axis) and implement
    ``_trace(footprint_bytes, xp)`` once over an array module, or override
    :meth:`device_trace` / :meth:`host_trace` when the two sides genuinely
    differ (pointer chase: ``lax.scan`` on device, a Python loop on host).

    Attributes
    ----------
    name : str
        Row label in sweep results and benchmarks.
    serial_deps : bool
        True when every access depends on the previous one (pointer
        chase): the timing model then collapses memory-level parallelism
        to 1 outstanding miss regardless of the CPU model — dependent
        loads cannot overlap, which is what makes the workload an
        idle-latency probe.
    """
    name: str = "workload"
    serial_deps: bool = False

    def _trace(self, footprint_bytes: int, xp) -> WorkloadTrace:
        raise NotImplementedError

    def device_trace(self, footprint_bytes: int) -> WorkloadTrace:
        """Generate the trace on device with pure ``jax`` ops.

        Parameters
        ----------
        footprint_bytes : int
            Working-set size; the §IV suite passes ``k * l2_bytes``.

        Returns
        -------
        WorkloadTrace
            ``jnp`` arrays, bitwise equal to :meth:`host_trace`.
        """
        return self._trace(footprint_bytes, jnp)

    def host_trace(self, footprint_bytes: int) -> WorkloadTrace:
        """NumPy reference generator — the parity oracle (same contract
        as :meth:`device_trace`, ``np`` arrays)."""
        return self._trace(footprint_bytes, np)

    def cpu_for(self, cpu: CPUModel) -> CPUModel:
        """CPU model that times this workload (MLP=1 for dependent loads)."""
        if self.serial_deps and cpu.effective_mlp != 1:
            return dataclasses.replace(cpu, mlp=1)
        return cpu


# ---------------------------------------------------------------------------
# STREAM as a Workload (the legacy generator, same contract)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stream(Workload):
    """The four STREAM kernels (:mod:`repro.core.stream`) under the
    workload contract; the engine's default axis entry.

    Parameters
    ----------
    kernel : str
        One of ``copy | scale | add | triad``.
    """
    kernel: str = "triad"

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"stream_{self.kernel}"

    def device_trace(self, footprint_bytes: int) -> WorkloadTrace:
        layout = stream_mod.layout_for_footprint(footprint_bytes)
        addr, is_write = stream_mod.stream_trace(self.kernel, layout)
        return WorkloadTrace(addr=addr, is_write=is_write,
                             n_pages=layout.n_pages)

    def host_trace(self, footprint_bytes: int) -> WorkloadTrace:
        layout = stream_mod.layout_for_footprint(footprint_bytes)
        reads, write = stream_mod._PATTERN[self.kernel]
        n = layout.n_elems
        line = np.arange(n, dtype=np.int32) // stream_mod.ELEMS_PER_LINE
        cols = [np.int32(layout.base_line(r)) + line for r in reads]
        cols.append(np.int32(layout.base_line(write)) + line)
        addr = np.stack(cols, axis=1).reshape(-1)
        is_write = np.tile(
            np.asarray([0] * len(reads) + [1], np.int32), n)
        return WorkloadTrace(addr=addr, is_write=is_write,
                             n_pages=layout.n_pages)


def lines_for_footprint(footprint_bytes: int) -> int:
    """Cachelines covering a footprint (floor, >= 2)."""
    return max(footprint_bytes // CACHELINE_BYTES, 2)


def pages_for_lines(n_lines: int) -> int:
    """4 KiB pages covering `n_lines` cachelines (ceil, >= 1)."""
    return max(-(-n_lines // LINES_PER_PAGE), 1)
