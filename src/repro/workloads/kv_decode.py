"""LLM KV-decode workload: paged-attention gathers from the serving stack.

The paper's motivating use-case is LLM inference with the KV cache spilled
to CXL.  This generator does not invent that traffic — it *records* it
from the framework's own serving stack:

1. a :class:`repro.memory.kvcache.PagedKVCache` pool is sized from the
   sweep footprint (so the §IV ``k x L2`` axis scales the pool);
2. a :class:`repro.serving.scheduler.ContinuousBatcher` admits a seeded
   request mix and runs the vLLM-style engine loop (prefill-priority,
   batched decode, preemption when the pool is exhausted);
3. every **decode** step records, at page granularity, the block-table
   gather of each running sequence (reads of the full context) and the
   appended token (a write) — with each page's HBM/CXL residency *at
   access time*, as the cache's LRU promotion/demotion moves it.

The page-granular log is tiny host state; the line-granular trace is then
expanded on device (:meth:`KVDecode.device_trace`) or in NumPy
(:meth:`KVDecode.host_trace`) by one shared routine — the parity pair the
benchmarks assert bitwise.  Because the generator carries its own
per-access tier intent (HBM -> DRAM target 0, CXL -> the expander
targets), the sweep's placement-policy axis is bypassed: placement is the
KV manager's decision, exactly like the paper's zNUMA placement is the
OS's.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.spec import CACHELINE_BYTES
from repro.memory.kvcache import CXL, PagedKVCache
from repro.memory.offload import kv_offload_tiers
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.workloads.base import Workload, WorkloadTrace, pages_for_lines

# One recorded decode step, page-granular:
# (read_pages, read_tiers, write_pages, write_line_offs, write_tiers)
StepLog = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass(frozen=True)
class KVDecode(Workload):
    """Paged-attention decode gathers with HBM/CXL page residency.

    Parameters
    ----------
    arch : str
        Architecture key (:func:`repro.configs.get_smoke`) supplying the
        KV head geometry.
    seed : int
        Drives the request mix (prompt/new-token lengths); the serving
        loop itself is deterministic.
    n_requests, max_running : int
        Offered load and the batcher's running-set cap; sized so the pool
        preempts occasionally at small footprints.
    page_size : int
        Tokens per KV page.
    hbm_fraction : float
        HBM page budget as a fraction of the pool — the rest of the
        working set lives on (or is demoted to) the CXL tier.
    max_pool_pages : int
        Pool-size cap, bounding trace length at large sweep footprints.
    ssd_cold_offload : int
        When positive, the CXL-DRAM page budget: CXL-resident pages
        beyond it — coldest first by the cache's LRU clock — are
        offloaded to the CXL-SSD tier and emit tier-2 intent, which
        :meth:`repro.core.route.RouteMap.targets_of_tiered_lines` routes
        to the flash expander (:func:`repro.memory.offload.
        kv_offload_tiers`).  0 (default) keeps the two-level HBM/CXL
        stream bitwise-unchanged.
    """
    arch: str = "granite-3-8b"
    seed: int = 3
    n_requests: int = 6
    max_running: int = 4
    page_size: int = 8
    hbm_fraction: float = 0.25
    max_pool_pages: int = 96
    ssd_cold_offload: int = 0

    name = "kv_decode"

    # -- scenario: run the real serving stack, record page-level refs -------
    def _scenario(self, footprint_bytes: int):
        return _kv_scenario(self, footprint_bytes)

    # -- trace expansion (shared device/host) --------------------------------
    def _trace(self, footprint_bytes: int, xp) -> WorkloadTrace:
        steps, lines_per_page, total_lines = self._scenario(footprint_bytes)
        line = xp.arange(lines_per_page, dtype=xp.int32)
        addrs, writes, tiers = [], [], []
        for rp, rt, wp, wo, wt in steps:
            if rp.shape[0]:
                a = (xp.asarray(rp, xp.int32)[:, None] * lines_per_page
                     + line[None, :]).reshape(-1)
                addrs.append(a)
                writes.append(xp.zeros(a.shape[0], xp.int32))
                tiers.append(xp.repeat(xp.asarray(rt, xp.int32),
                                       lines_per_page))
            if wp.shape[0]:
                a = (xp.asarray(wp, xp.int32) * lines_per_page
                     + xp.asarray(wo, xp.int32))
                addrs.append(a)
                writes.append(xp.ones(a.shape[0], xp.int32))
                tiers.append(xp.asarray(wt, xp.int32))
        if not addrs:
            raise ValueError("kv_decode scenario recorded no decode steps")
        return WorkloadTrace(addr=xp.concatenate(addrs),
                             is_write=xp.concatenate(writes),
                             n_pages=pages_for_lines(total_lines),
                             tier=xp.concatenate(tiers))

    def device_trace(self, footprint_bytes: int) -> WorkloadTrace:
        return self._trace(footprint_bytes, jnp)

    def host_trace(self, footprint_bytes: int) -> WorkloadTrace:
        return self._trace(footprint_bytes, np)


@functools.lru_cache(maxsize=32)
def _kv_scenario(wl: KVDecode, footprint_bytes: int
                 ) -> Tuple[Tuple[StepLog, ...], int, int]:
    """Run the serving stack once and log decode-step page references.

    Returns ``(steps, lines_per_page, total_lines)``; cached per
    (workload, footprint) — the run is deterministic under ``wl.seed``, so
    the cache is a speedup, not a semantic.
    """
    cfg = get_smoke(wl.arch)
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    page_bytes = wl.page_size * kh * hd * 2 * 2          # K+V, 2 B each
    pool = max(4, min(footprint_bytes // page_bytes, wl.max_pool_pages))
    kv = PagedKVCache(cfg, n_pages=pool, page_size=wl.page_size,
                      max_blocks=pool,
                      hbm_page_budget=max(1, int(pool * wl.hbm_fraction)),
                      n_layers=1)
    lines_per_page = kv.lines_per_page()
    token_bytes = max(page_bytes // wl.page_size, 1)

    rng = np.random.default_rng(wl.seed)
    pool_tokens = pool * wl.page_size
    # offered load scales with the *requested* footprint (bounded at 2x the
    # pool): past the pool cap, bigger footprints mean longer sequences
    # against the same capacity — more demotion/preemption pressure, which
    # is exactly the capacity regime the CXL tier exists for
    offered = min((footprint_bytes // page_bytes) * wl.page_size,
                  2 * pool_tokens)
    budget = max(offered // (wl.n_requests + 2), 2 * wl.page_size)
    cap = max(pool_tokens // 2, wl.page_size + 1)
    batcher = ContinuousBatcher(kv, max_running=wl.max_running)
    for rid in range(wl.n_requests):
        prompt = int(rng.integers(budget // 2, budget + 1))
        new = int(rng.integers(budget // 4 + 1, budget // 2 + 1))
        if prompt + new > cap:
            prompt = max(1, cap - new)
        batcher.submit(Request(rid=rid, prompt_len=prompt,
                               max_new_tokens=new))

    zeros = lambda t: np.zeros((t, kh, hd), np.float32)
    steps: List[StepLog] = []

    def prefill_fn(req: Request) -> None:
        kv.append_tokens(req.rid, 0, zeros(req.prompt_len),
                         zeros(req.prompt_len))

    def tier3(snapshot):
        # cold-CXL -> SSD demotion from the cache's own LRU clock
        return kv_offload_tiers(snapshot, kv.last_use,
                                cxl_page_budget=wl.ssd_cold_offload)

    def decode_fn(seq_ids):
        tier_now = kv.tier_snapshot()          # residency at access time
        tmap = tier3(tier_now) if wl.ssd_cold_offload > 0 else None
        rp: List[int] = []
        rt: List[int] = []
        for sid in seq_ids:                    # context gather, page-major
            table = kv.block_tables[sid]
            rp.extend(table)
            rt.extend((int(tier_now[p] == CXL) if tmap is None
                       else int(tmap[p])) for p in table)
        kv.gather_args(seq_ids)                # charge fetches, promote hot
        wp, wo, wt, out = [], [], [], {}
        for sid in seq_ids:                    # append this step's token
            kv.append_tokens(sid, 0, zeros(1), zeros(1))
            pos = kv.seq_lens[sid] - 1
            page = kv.block_tables[sid][pos // wl.page_size]
            off = min((pos % wl.page_size) * token_bytes // CACHELINE_BYTES,
                      lines_per_page - 1)
            wp.append(page)
            wo.append(off)
            wt.append(int(kv.tier[page] == CXL) if wl.ssd_cold_offload <= 0
                      else int(tier3(kv.tier_snapshot())[page]))
            out[sid] = 0
        steps.append((np.asarray(rp, np.int32), np.asarray(rt, np.int32),
                      np.asarray(wp, np.int32), np.asarray(wo, np.int32),
                      np.asarray(wt, np.int32)))
        return out

    batcher.run_until_drained(prefill_fn, decode_fn, max_steps=2000)
    return tuple(steps), lines_per_page, pool * lines_per_page
