"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from saved runs.

    PYTHONPATH=src python -m repro.roofline.report \
        --baseline experiments/dryrun --optimized experiments/dryrun_opt
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import List

from repro.roofline.analysis import Roofline, analyze_dir


def dryrun_table(dirpath: pathlib.Path, mesh_tag: str) -> str:
    rows = []
    for jf in sorted(dirpath.glob(f"*__{mesh_tag}.json")):
        m = json.loads(jf.read_text())
        rows.append(m)
    out = ["| arch | shape | status | compile_s | flops/dev | mem/dev GiB | "
           "note |",
           "|---|---|---|---|---|---|---|"]
    for m in rows:
        out.append(
            f"| {m['arch']} | {m['shape']} | {m['status']} | "
            f"{m['compile_s']} | {m['flops']:.2e} | "
            f"{m['peak_memory_per_device']/2**30:.2f} | {m['note'][:70]} |")
    return "\n".join(out)


def roofline_table(rows: List[Roofline]) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | cxl_s | "
           "dominant | MODEL/HLO | MFU-bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.2e} | {r.memory_s:.2e} "
            f"| {r.collective_s:.2e} | {r.cxl_s:.2e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.mfu_bound:.1%} |")
    return "\n".join(out)


def compare_table(base: List[Roofline], opt: List[Roofline]) -> str:
    bidx = {(r.arch, r.shape): r for r in base}
    out = ["| arch | shape | MFU before | MFU after | coll_s before | "
           "coll_s after | speedup(bound) |",
           "|---|---|---|---|---|---|---|"]
    for r in opt:
        b = bidx.get((r.arch, r.shape))
        if b is None:
            continue
        b_bound = max(b.terms().values())
        r_bound = max(r.terms().values())
        out.append(
            f"| {r.arch} | {r.shape} | {b.mfu_bound:.1%} | "
            f"**{r.mfu_bound:.1%}** | {b.collective_s:.1f} | "
            f"{r.collective_s:.1f} | {b_bound/max(r_bound,1e-9):.1f}x |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--optimized", default="experiments/dryrun_opt")
    ap.add_argument("--out", default="experiments/tables")
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    base = analyze_dir(args.baseline, "16x16")
    (outdir / "roofline_baseline.md").write_text(roofline_table(base))
    roof = pathlib.Path("experiments/roofline")
    roof.mkdir(parents=True, exist_ok=True)
    (roof / "baseline.json").write_text(
        json.dumps([r.row() for r in base], indent=1))
    (outdir / "dryrun_16x16.md").write_text(
        dryrun_table(pathlib.Path(args.baseline), "16x16"))
    (outdir / "dryrun_2x16x16.md").write_text(
        dryrun_table(pathlib.Path(args.baseline), "2x16x16"))

    opt_dir = pathlib.Path(args.optimized)
    if opt_dir.exists() and list(opt_dir.glob("*__16x16.json")):
        opt = analyze_dir(args.optimized, "16x16")
        (outdir / "roofline_optimized.md").write_text(roofline_table(opt))
        (roof / "optimized.json").write_text(
            json.dumps([r.row() for r in opt], indent=1))
        (outdir / "before_after.md").write_text(compare_table(base, opt))
        (outdir / "dryrun_opt_2x16x16.md").write_text(
            dryrun_table(opt_dir, "2x16x16"))
    print(f"tables written to {outdir}")


if __name__ == "__main__":
    main()
