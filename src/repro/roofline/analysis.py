"""Roofline assembly: three HLO-derived terms + the CXL tier term.

Per (arch x shape x mesh) cell, from the saved dry-run HLO:

    compute_s    = HLO_dot_flops_per_device / 197e12        (bf16 peak, v5e)
    memory_s     = HLO_traffic_bytes_per_device / 819e9     (HBM bw)
    collective_s = ring-corrected collective bytes / 50e9   (ICI per link)
    cxl_s        = tiering-plan off-HBM traffic / calibrated CXL path

plus MODEL_FLOPS (the analytic 6*N*D convention) and the useful-compute
ratio MODEL/HLO that flags remat/dispatch waste.  The dominant term is the
hillclimb target (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import spec as hw
from repro.memory import tiering
from repro.models.model import SHAPES, ShapeCell
from repro.roofline import hlo_analysis

PEAK_FLOPS = hw.TPU_V5E_BF16_FLOPS
HBM_BW = hw.TPU_V5E_HBM_GBPS
ICI_BW = hw.TPU_V5E_ICI_GBPS


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6*N*D convention; excludes remat recompute)
# ---------------------------------------------------------------------------
def _attn_flops_token(cfg: ModelConfig, ctx: float) -> float:
    """Forward attention matmul flops per token per ATTENTION layer."""
    if cfg.attn_kind == "mla" and cfg.mla:
        dims = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return 4.0 * cfg.n_heads * dims * ctx
    eff_ctx = min(ctx, cfg.window) if cfg.window else ctx
    return 4.0 * cfg.n_heads * cfg.head_dim * eff_ctx


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    n_act = cfg.n_active_params()
    attn_layers = sum(1 for k in cfg.layer_kinds() if k in ("attn", "moe"))
    rwkv_layers = sum(1 for k in cfg.layer_kinds() if k == "rwkv")
    hd = cfg.rwkv_head_dim
    rwkv_tok = 6.0 * cfg.d_model * hd * rwkv_layers     # WKV state math
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = b * s
        attn = attn_layers * _attn_flops_token(cfg, s / 2.0)
        return tokens * (6.0 * n_act + 3.0 * (attn + rwkv_tok))
    if cell.kind == "prefill":
        tokens = b * s
        attn = attn_layers * _attn_flops_token(cfg, s / 2.0)
        return tokens * (2.0 * n_act + attn + rwkv_tok)
    # decode: one token against ctx = seq_len
    attn = attn_layers * _attn_flops_token(cfg, float(s))
    return b * (2.0 * n_act + attn + rwkv_tok)


# ---------------------------------------------------------------------------
# Per-cell roofline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float               # fusion-ideal (headline)
    memory_hi_s: float            # all-instruction ceiling (diagnostic)
    collective_s: float
    cxl_s: float
    dominant: str
    hlo_flops_dev: float
    traffic_dev: float
    coll_bytes_dev: float
    model_flops_total: float
    useful_ratio: float           # MODEL / (HLO x chips)
    mfu_bound: float              # model compute time / dominant bound
    bytes_per_device: int
    warnings: List[str]
    next_action: str = ""

    def terms(self) -> Dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s, "cxl": self.cxl_s}

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def _suggestion(dom: str, r: "Roofline", cfg: ModelConfig) -> str:
    if dom == "memory":
        return ("memory-bound: raise arithmetic intensity — larger fused "
                "blocks (Pallas flash kernel on TPU), wider microbatch, or "
                "bf16 logits to cut LM-head traffic")
    if dom == "collective":
        return ("collective-bound: move the all-reduce earlier (overlap "
                "with compute), reduce-scatter+all-gather the gradients, "
                "or shrink TP degree for this layer")
    if dom == "cxl":
        return ("CXL-bound: deepen prefetch overlap or increase HBM-resident "
                "fraction (tiering plan)")
    return ("compute-bound: good — push MFU via kernel fusion and keep "
            "collectives overlapped")


def analyze_cell(arch: str, shape: str, mesh_tag: str, hlo_text: str,
                 bytes_per_device: int = 0) -> Roofline:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    chips = 512 if mesh_tag == "2x16x16" else 256
    a = hlo_analysis.analyze(hlo_text)
    compute_s = a.flops / PEAK_FLOPS
    # memory term bracketed: `hi` counts every post-fusion instruction's
    # operands+outputs (CPU-backend fusion is weaker than TPU's, so this
    # over-counts on a real pod); `lo` is fusion-ideal — only dot operands/
    # outputs cross HBM<->VMEM (what the Pallas kernels + XLA:TPU achieve).
    # The headline roofline uses `lo`; `hi` is the diagnostic ceiling.
    memory_hi_s = a.traffic_bytes / HBM_BW
    memory_s = a.dot_traffic_bytes / HBM_BW
    collective_s = a.total_collective_bytes / ICI_BW
    # CXL term from the tiering plan (training spills / cold-KV serving)
    if cell.kind == "train":
        plan = tiering.plan_training(cfg, n_devices=chips,
                                     batch=cell.global_batch,
                                     seq=cell.seq_len)
    else:
        plan = tiering.plan_serving(cfg, n_devices=chips,
                                    batch=cell.global_batch,
                                    context=cell.seq_len)
    cxl_s = plan.cxl_seconds
    mf = model_flops(cfg, cell)
    useful = mf / max(a.flops * chips, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s, "cxl": cxl_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mfu_bound = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    r = Roofline(arch=arch, shape=shape, mesh=mesh_tag, chips=chips,
                 compute_s=compute_s, memory_s=memory_s,
                 memory_hi_s=memory_hi_s,
                 collective_s=collective_s, cxl_s=cxl_s, dominant=dominant,
                 hlo_flops_dev=a.flops, traffic_dev=a.traffic_bytes,
                 coll_bytes_dev=a.total_collective_bytes,
                 model_flops_total=mf, useful_ratio=useful,
                 mfu_bound=min(mfu_bound, 1.0),
                 bytes_per_device=bytes_per_device,
                 warnings=a.warnings[:3])
    r.next_action = _suggestion(dominant, r, cfg)
    return r


def analyze_dir(dryrun_dir: str | pathlib.Path,
                mesh_tag: str = "16x16") -> List[Roofline]:
    d = pathlib.Path(dryrun_dir)
    rows: List[Roofline] = []
    for jf in sorted(d.glob(f"*__{mesh_tag}.json")):
        meta = json.loads(jf.read_text())
        if meta["status"] != "ok":
            continue
        hlo_file = d / "hlo" / (jf.stem + ".txt")
        if not hlo_file.exists():
            continue
        rows.append(analyze_cell(meta["arch"], meta["shape"], mesh_tag,
                                 hlo_file.read_text(),
                                 meta.get("peak_memory_per_device", 0)))
    return rows


def to_markdown(rows: List[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "cxl_s | dominant | MODEL/HLO | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | {r.cxl_s:.2e} | "
            f"**{r.dominant}** | {r.useful_ratio:.2f} | "
            f"{r.mfu_bound:.1%} |\n")
    return "".join(out)
