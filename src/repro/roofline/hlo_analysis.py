"""Static analyzer for optimized (post-SPMD) HLO text.

`compiled.cost_analysis()` counts a while-loop body ONCE, not times its
trip count — useless for scanned transformers (94-layer loops).  This
module re-derives per-device costs from `compiled.as_text()` with correct
loop multipliers:

  * **flops** — every `dot` (2 x output-elements x contraction size), with
    fused computations attributed at their call sites and while bodies
    multiplied by parsed trip counts;
  * **traffic bytes** — per top-level instruction: output + operand bytes
    (a post-fusion instruction ~= one kernel launch; its operands/outputs
    are the HBM round trips).  Upper-bound proxy, consistent across cells;
  * **collective bytes** — all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, ring-corrected ((g-1)/g, all-reduce
    x2), multiplied into loops like everything else.

Trip counts come from the loop condition: `compare(get-tuple-element,
constant(N)), direction=LT` — the shape XLA emits for `lax.scan`.  Loops
whose bound can't be parsed get multiplier 1 and are reported in
`warnings` (never silently wrong).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST = re.compile(r"constant\((\d+)\)")
_KNOWN_TRIPS = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) of all array shapes in a type string."""
    elems = bytes_ = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * DTYPE_BYTES[dt]
    return elems, bytes_


def _first_shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    opcode: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    # symbol table: value name -> dims of its (first) array shape
    types: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    # value name -> bytes of its (first) array shape
    nbytes: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    dot_traffic: float = 0.0      # fusion-ideal: dot operands/outputs only
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        self.dot_traffic += other.dot_traffic * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)


@dataclasses.dataclass
class Analysis:
    flops: float
    traffic_bytes: float
    dot_traffic_bytes: float
    collective_bytes: Dict[str, float]
    collective_count: Dict[str, int]
    warnings: List[str]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_PARAM_DECL = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))")


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEAD.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                # parameter types from the header signature
                sig = line[line.find("("):line.rfind("->")]
                for pname, ptype in _PARAM_DECL.findall(sig):
                    sh = _first_shape_dims(ptype)
                    if sh:
                        cur.types[pname] = sh[1]
                        cur.nbytes[pname] = _shape_elems_bytes(ptype)[1]
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # opcode = first word after the result type
        after_type = re.sub(r"^\([^)]*\)\s*", "",
                            re.sub(r"^[a-z0-9]+\[[0-9,]*\]\{?[0-9,]*\}?\s*",
                                   "", rhs))
        opm = re.match(r"([\w\-]+)", after_type)
        opcode = opm.group(1) if opm else ""
        is_root = raw.lstrip().startswith("ROOT")
        sh = _first_shape_dims(rhs)
        if sh:
            cur.types[name] = sh[1]
            # result type is everything before the opcode token
            cur.nbytes[name] = _shape_elems_bytes(
                rhs.split(opcode)[0] if opcode else rhs)[1]
        cur.instrs.append(Instr(name, rhs, opcode, is_root))
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation,
               warnings: List[str]) -> float:
    """2 * out_elems * contraction_size for one dot instruction."""
    out = _first_shape_dims(ins.rhs)
    if out is None:
        return 0.0
    _, out_dims = out
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # lhs operand: inline type or first %name inside dot(...); dims via the
    # symbol table.  The inline form is `f32[256,256]{1,0} %name, ...` — it
    # must be matched at the start of the operand list (splitting on "," would
    # truncate multi-dim shapes at the comma inside the brackets).
    inside = ins.rhs[ins.rhs.find("dot(") + 4:]
    lhs_dims: Optional[List[int]] = None
    inline = _SHAPE.match(inside.lstrip())
    if inline:                       # operand type written inline
        lhs_dims = [int(d) for d in inline.group(2).split(",") if d]
    else:
        mo = re.match(r"\s*%?([\w.\-]+)", inside)
        if mo:
            lhs_dims = comp.types.get(mo.group(1))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    contraction = 1
    if lhs_dims is not None and m:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contraction *= lhs_dims[int(idx)]
    else:
        warnings.append(f"dot {ins.name}: lhs shape unresolved; "
                        "contraction=1 undercount")
    return 2.0 * out_elems * contraction


def _group_size(rhs: str, default: int = 2) -> int:
    m = _GROUPS_IOTA.search(rhs)
    if m:
        return int(m.group(2))
    m = _GROUPS.search(rhs)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def _trip_count(cond: Computation, warnings: List[str]) -> float:
    """Parse `while` trip count from the scan-shaped condition.

    XLA lowers `lax.scan` to `while(i < N)`; the compare may be wrapped in
    a kLoop fusion (`%root = fusion(%i, %constant_N), calls=...compare`).
    Strategy: take the s32 constant operand of the ROOT instruction;
    fall back to the largest s32 constant in the computation.
    """
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        m = _CONST.search(ins.rhs)
        if m and ins.rhs.strip().startswith("s32[]"):
            consts[ins.name] = int(m.group(1))
    root = next((i for i in cond.instrs if i.is_root), None)
    if root is not None:
        ops = re.findall(r"%([\w.\-]+)", root.rhs)
        hits = [consts[o] for o in ops if o in consts]
        if hits:
            return float(max(hits))
        m = _CONST.search(root.rhs)
        if m:
            return float(m.group(1))
    if consts:
        return float(max(consts.values()))
    warnings.append(f"trip count unresolved for cond {cond.name}; using 1")
    return 1.0


def analyze(hlo: str) -> Analysis:
    comps, entry = parse_computations(hlo)
    warnings: List[str] = []
    memo: Dict[str, Cost] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        comp = comps[name]
        c = Cost()
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                c.flops += _dot_flops(ins, comp, warnings)
                # fusion-ideal traffic: operands + output of the dot
                dt = comp.nbytes.get(ins.name, 0)
                inside = ins.rhs[ins.rhs.find("dot(") + 4:]
                for om in re.findall(r"%([\w.\-]+)", inside.split(")")[0]):
                    dt += comp.nbytes.get(om, 0)
                c.dot_traffic += dt
            # collectives
            for coll in COLLECTIVES:
                if op == coll or op == coll + "-start":
                    _, out_bytes = _shape_elems_bytes(
                        ins.rhs.split(op)[0])
                    g = _group_size(ins.rhs)
                    ring = (g - 1) / g if g > 1 else 1.0
                    factor = 2.0 if coll == "all-reduce" else 1.0
                    wire = out_bytes * ring * factor
                    c.coll_bytes[coll] = c.coll_bytes.get(coll, 0.0) + wire
                    c.coll_count[coll] = c.coll_count.get(coll, 0) + 1
                    break
            # traffic
            if op not in _SKIP_TRAFFIC and not op.endswith("-done"):
                _, total_bytes = _shape_elems_bytes(ins.rhs)
                c.traffic += total_bytes
            # children
            if op == "while":
                m = _CALL_ATTR.findall(ins.rhs)
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                # prefer XLA's own annotation; fall back to the condition
                km = _KNOWN_TRIPS.search(ins.rhs)
                if km:
                    trips = float(km.group(1))
                elif cond in comps:
                    trips = _trip_count(comps[cond], warnings)
                else:
                    trips = 1.0
                if body:
                    c.add(cost_of(body, stack + (name,)), trips)
            elif op in ("fusion", "call", "custom-call", "reduce",
                        "reduce-window", "scatter", "sort", "map",
                        "all-reduce", "reduce-scatter", "select-and-scatter"):
                for attr in ("calls", "to_apply"):
                    m = re.search(attr + r"=%?([\w.\-]+)", ins.rhs)
                    if m:
                        c.add(cost_of(m.group(1), stack + (name,)), 1.0)
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                if m:
                    branches = [b.strip().lstrip("%")
                                for b in m.group(1).split(",")]
                    costs = [cost_of(b, stack + (name,)) for b in branches]
                    if costs:
                        best = max(costs, key=lambda x: x.flops + x.traffic)
                        c.add(best, 1.0)
        memo[name] = c
        return c

    total = cost_of(entry)
    return Analysis(flops=total.flops, traffic_bytes=total.traffic,
                    dot_traffic_bytes=total.dot_traffic,
                    collective_bytes=dict(total.coll_bytes),
                    collective_count=dict(total.coll_count),
                    warnings=warnings)
