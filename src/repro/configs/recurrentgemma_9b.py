"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (local-MQA kv=1) ff=12288
vocab=256000. RG-LRU + local attention, 2 recurrent : 1 attention.

[arXiv:2402.19427 Griffin; unverified]. Pattern (rec, rec, attn) x 12 +
(rec, rec); local attention window 2048; RG-LRU width 4096 with width-4
causal conv. Sub-quadratic => long_500k runs.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    attn_kind="swa", window=2048, rope="rope", rope_theta=10_000.0,
    lru_width=4096, conv_width=4,
    sub_quadratic=True, act="gelu",
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=160, vocab_size=512, window=16, lru_width=64, kv_chunk=16)
