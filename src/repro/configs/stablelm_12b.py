"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) ff=13824 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b family; hf-verified]. Per-head qk-norm as in
StableLM-2-12B. Full attention => long_500k skipped (DESIGN.md §6).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100352,
    attn_kind="full", rope="rope", rope_theta=10_000.0, qk_norm=True,
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, kv_chunk=32)
