"""Model/architecture configuration schema.

One :class:`ModelConfig` describes every assigned architecture (DESIGN.md §6)
plus the reduced smoke variants.  `block_pattern` drives the transformer
assembly: a cycle of block kinds over the depth, e.g. ``("attn",)`` for dense
LMs, ``("rec", "rec", "attn")`` for recurrentgemma's 2:1 hybrid,
``("rwkv",)`` for RWKV6, ``("moe",)`` / ``("dense*3", "moe*rest")`` via
`first_dense` for the MoE archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BlockKind = str  # 'attn' | 'moe' | 'rwkv' | 'rec'


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 128
    top_k: int = 8
    n_shared: int = 0              # shared (always-on) experts
    expert_d_ff: int = 1536
    shared_d_ff: int = 0           # d_ff of the shared expert (0 => expert_d_ff)
    first_dense: int = 0           # leading dense layers (deepseek: 3)
    dense_d_ff: int = 0            # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # 'dense' | 'ssm' | 'vlm' | 'moe' | 'audio' | 'hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads

    block_pattern: Tuple[BlockKind, ...] = ("attn",)

    # attention flavour
    attn_kind: str = "full"        # 'full' | 'swa' | 'mla'
    window: Optional[int] = None   # SWA / local-attn window
    rope: str = "rope"             # 'rope' | 'mrope' | 'none' (sinusoidal)
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    qk_norm: bool = False

    # mixture of experts
    moe: Optional[MoEConfig] = None
    # multi-head latent attention
    mla: Optional[MLAConfig] = None

    # rwkv6 / rg-lru
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 0            # 0 = step scan; >0 = chunk-parallel WKV
    lru_width: int = 0             # 0 => d_model
    conv_width: int = 4

    # embeddings / heads
    n_codebooks: int = 1           # musicgen: 4
    tie_embeddings: bool = False
    vision_tokens: int = 0         # qwen2-vl stub frontend tokens
    vision_dim: int = 0

    act: str = "silu"
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # distribution
    fsdp: bool = False             # ZeRO-3 weight sharding over the data axis
    attn_seq_shard: bool = False   # shard q-seq (not heads) over model axis
    kv_chunk: int = 1024           # blockwise-attention KV chunk
    strategy: str = "tp"           # 'tp' | 'dp' (pure DP + ZeRO-3)
    remat_policy: str = "none"     # 'none' (full remat) | 'dots' (save dots)
    tp_reduce_bf16: bool = False   # bf16 wire on TP-boundary all-reduces
                                   # (lowering-only on CPU: smoke configs
                                   # keep False, see configs.get_smoke)

    # serving
    sub_quadratic: bool = False    # eligible for long_500k
    kv_tiering: bool = True        # CXL KV-cache tiering applicable

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived -----------------------------------------------------------
    def layer_kinds(self) -> Tuple[BlockKind, ...]:
        """Expand block_pattern over depth (+ first_dense override for MoE)."""
        kinds = []
        for i in range(self.n_layers):
            k = self.block_pattern[i % len(self.block_pattern)]
            if (k == "moe" and self.moe is not None
                    and i < self.moe.first_dense):
                k = "attn"
            kinds.append(k)
        return tuple(kinds)

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        total = v * d * self.n_codebooks            # embed
        if not self.tie_embeddings:
            total += d * v * self.n_codebooks       # head(s)
        for k in self.layer_kinds():
            if k in ("attn", "moe"):
                if self.attn_kind == "mla" and self.mla:
                    m = self.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += (d * m.q_lora_rank
                              + m.q_lora_rank * self.n_heads * qk
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * self.n_heads *
                              (m.qk_nope_head_dim + m.v_head_dim)
                              + self.n_heads * m.v_head_dim * d)
                else:
                    total += d * (n_q + 2 * n_kv) + n_q * d
            if k == "attn":
                ff = (self.moe.dense_d_ff if self.moe and self.moe.dense_d_ff
                      else f)
                total += 3 * d * ff
            elif k == "moe":
                if not self.moe:
                    raise ValueError(
                        "block kind 'moe' requires a MoE config")
                total += d * self.moe.n_experts     # router
                total += self.moe.n_experts * 3 * d * self.moe.expert_d_ff
                sh = self.moe.shared_d_ff or self.moe.expert_d_ff
                total += self.moe.n_shared * 3 * d * sh
            elif k == "rwkv":
                # time-mix (r,k,v,w,g,o) + channel-mix (~3.5 d^2) + loras
                total += 6 * d * d + 3.5 * d * d
            elif k == "rec":
                w = self.lru_width
                total += 2 * d * w + w * d + self.conv_width * w + 3 * w
                total += 3 * d * f
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe
        full = self.n_params()
        n_moe_layers = sum(1 for k in self.layer_kinds() if k == "moe")
        inactive = n_moe_layers * (m.n_experts - m.top_k) * 3 * d * m.expert_d_ff
        return int(full - inactive)
