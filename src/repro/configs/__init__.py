"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES = {
    "stablelm-12b": "stablelm_12b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "granite-3-8b": "granite_3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "musicgen-large": "musicgen_large",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    import dataclasses
    # bf16-wire dots don't execute on the CPU backend; smoke configs run
    return dataclasses.replace(_mod(arch).smoke(), tp_reduce_bf16=False)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
