"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, expert-ff=2048,
vocab=129280, MoE 1 shared + 256 routed top-8.

[arXiv:2412.19437; hf-verified]. MLA (q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128); first 3 layers dense (ff 18432); decode uses the absorbed
latent formulation over the 9x-smaller {ckv,krope} cache. MTP head omitted
(training-objective add-on; noted in DESIGN.md). fsdp=True — and even then
optimizer state exceeds single-pod HBM: the paper-representative CXL
offload cell (EXPERIMENTS.md §Dry-run).
"""
import dataclasses
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=2048, vocab_size=129280,
    block_pattern=("moe",),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, expert_d_ff=2048,
                  shared_d_ff=2048, first_dense=3, dense_d_ff=18432,
                  capacity_factor=1.25),
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    rope="rope", rope_theta=10_000.0,
    fsdp=True,
    tp_reduce_bf16=True, remat_policy="dots",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=64, vocab_size=512, kv_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, expert_d_ff=64,
                      shared_d_ff=64, first_dense=1, dense_d_ff=128),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        fsdp=False)
