"""musicgen-large [audio]: 48L d=2048 32H (MHA kv=32) ff=8192 vocab=2048.

[arXiv:2306.05284; hf-verified]. Decoder-only over EnCodec tokens: 4
codebooks (summed embeddings, 4 LM heads), sinusoidal positions. The
EnCodec frontend and the codebook delay pattern are data-pipeline stubs:
input_specs() supplies (B, 4, S) token ids.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    attn_kind="full", rope="none",
    n_codebooks=4, act="gelu",
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=160, vocab_size=128, n_codebooks=2, kv_chunk=32)
