"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) ff=8960 vocab=151936.

[arXiv:2409.12191; hf-verified]. M-RoPE (sections 16/24/24 over head_dim
128), dynamic-resolution vision frontend STUBBED: input_specs() supplies
precomputed patch embeddings (B, 256, 1280) that replace the leading
sequence positions. 12 heads don't divide tp=16 => sequence sharding.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    attn_kind="full", rope="mrope", rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    vision_tokens=256, vision_dim=1280,
    attn_seq_shard=True,
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, mrope_sections=(4, 2, 2),
        vision_tokens=4, vision_dim=32, kv_chunk=32)
