"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) expert-ff=1536
vocab=151936, MoE 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B family; hf-verified]. qk-norm; no shared expert.
235B total / ~22B active. fsdp=True: weights+optimizer ZeRO-3 over the
data axis (29 GiB/device unsharded would exceed v5e HBM).
"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    block_pattern=("moe",),
    moe=MoEConfig(n_experts=128, top_k=8, n_shared=0, expert_d_ff=1536,
                  capacity_factor=1.25),
    attn_kind="full", rope="rope", rope_theta=1_000_000.0, qk_norm=True,
    fsdp=True,
    tp_reduce_bf16=True, remat_policy="dots",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=512, kv_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=64), fsdp=False)
