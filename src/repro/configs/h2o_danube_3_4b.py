"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) ff=10240 vocab=32000.

[arXiv:2401.16818; unverified]. Llama+Mistral mix with sliding-window
attention (window 4096) => sub-quadratic, long_500k runs with a rolling
window cache.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    attn_kind="swa", window=4096, rope="rope", rope_theta=10_000.0,
    sub_quadratic=True,
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, window=16, kv_chunk=16)
