"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) ff=12288 vocab=49152.

[arXiv:2402.19173; hf-verified]. GQA, RoPE. 24 heads don't divide the
16-way model axis => query-sequence sharding strategy (DESIGN.md §4).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    attn_kind="full", rope="rope", rope_theta=100_000.0,
    attn_seq_shard=True,
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=60, n_heads=6, n_kv_heads=2, head_dim=10,
        d_ff=128, vocab_size=512, kv_chunk=32)
