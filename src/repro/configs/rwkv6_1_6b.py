"""rwkv6-1.6b [ssm]: 24L d=2048 (attention-free) ff=7168 vocab=65536.

[arXiv:2404.05892 "Finch"; unverified]. Data-dependent decay WKV6
recurrence; no KV cache => kv_tiering inapplicable (state+optimizer
tiering applies); sub-quadratic => long_500k runs.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    block_pattern=("rwkv",), rwkv_head_dim=64, rwkv_chunk=64,
    sub_quadratic=True, kv_tiering=False,
    tp_reduce_bf16=True, strategy="dp", remat_policy="dots",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=224, vocab_size=512, rwkv_head_dim=32)
