"""granite-3-8b [dense]: 40L d=4096 32H (GQA kv=8) ff=12800 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base family; hf-verified]. GQA, RoPE.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49155,
    attn_kind="full", rope="rope", rope_theta=10_000.0,
    tp_reduce_bf16=True, remat_policy="dots", strategy="dp",
)

def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=515, kv_chunk=32)
